"""The hardware-target abstraction (DESIGN.md §1).

KForge's central claim is platform-agnosticism: the same synthesis loop
retargets to a new accelerator given (a) a hardware profile for the
performance model, (b) a prompt descriptor + one-shot example in the
target's idiom, and (c) the platform-specific legality/alignment rules.
:class:`Platform` bundles exactly those degrees of freedom, so every layer
that used to hardcode TPU v5e (candidates.model_time, RuleBasedAnalyzer,
verification, prompts, the campaign runner) takes a platform instead.

Platforms are plain frozen dataclasses registered by name
(:mod:`repro.platforms.registry`); ``resolve`` accepts a name, an instance,
or ``None`` (the default target) so call sites stay one-liner-cheap.

This package is an import leaf: nothing here imports from ``repro.core`` or
``repro.roofline`` (both import *us*), which is what lets the profile be
threaded everywhere without cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Mapping, Optional, Union


@dataclasses.dataclass(frozen=True)
class Platform:
    """One hardware target: roofline constants + codegen/prompt idiom.

    ``matrix_align`` is the matrix-unit tile width (128 for a TPU MXU,
    16 for a tensor-core-class GPU profile); ``vector_align`` the sublane /
    warp granularity. ``fast_mem_bytes`` is the per-kernel working-set
    budget (VMEM on TPU, shared-memory+register tiling budget on GPU) the
    performance model uses for tile legality. ``max_tile`` caps a single
    block dimension — it is what makes the candidate SPACES genuinely
    platform-dependent (see ``candidates.space_for``).
    """
    name: str
    descriptor: str                 # prompt-facing accelerator name
    # -- roofline constants (per chip) --------------------------------------
    peak_flops: float               # matrix-unit peak, FLOP/s
    hbm_bw: float                   # main-memory bandwidth, B/s
    link_bw: float                  # interconnect bandwidth per link, B/s
    hbm_bytes: float                # main-memory capacity
    fast_mem_bytes: float           # VMEM / shared-memory working set
    # -- tiling / legality ---------------------------------------------------
    matrix_align: int               # MXU / tensor-core tile width
    vector_align: int               # sublane rows / warp width
    max_tile: int = 8192            # largest legal single block dimension
    # -- performance-model shape --------------------------------------------
    vpu_ratio: float = 8.0          # elementwise peak = peak_flops/vpu_ratio
    grid_step_overhead_s: float = 2e-8   # per-grid-step launch/bubble cost
    seq_step_latency_s: float = 5e-7     # per-sequential-step latency
    # -- synthesis idiom -----------------------------------------------------
    oneshot_example: str = ""       # one-shot kernel example (prompt)
    constraints_note: str = ""      # prompt text: working set + alignment
    # op -> {param: value} merged over candidates.REFERENCE_HINTS whenever
    # a reference is injected while synthesizing FOR this platform: how
    # transferred kernels idiomatically land on this target
    reference_hints: Mapping[str, Mapping[str, Any]] = \
        dataclasses.field(default_factory=dict)
    # compiler-params hook: builds backend compiler params (Mosaic on TPU)
    compiler_params_fn: Optional[Callable[..., Any]] = None

    @property
    def hw(self) -> Dict[str, float]:
        """The roofline dict historically known as ``HW_V5E``."""
        return {
            "peak_flops": self.peak_flops,
            "hbm_bw": self.hbm_bw,
            "ici_bw": self.link_bw,
            "hbm_bytes": self.hbm_bytes,
            "vmem_bytes": self.fast_mem_bytes,
        }

    def compiler_params(self, **kwargs) -> Any:
        """Backend compiler params for a kernel (e.g. Mosaic
        dimension_semantics on TPU); platforms without a compiler hook echo
        the kwargs so callers can forward them to a simulator."""
        if self.compiler_params_fn is None:
            return dict(kwargs)
        return self.compiler_params_fn(**kwargs)

    def align_target(self, choices, current: int) -> Optional[int]:
        """Smallest legal choice that is matrix-aligned, or None.

        Used by initial-candidate biasing and the analysis agent's Rule 1:
        only meaningful when ``current`` is misaligned for this platform.
        """
        if current % self.matrix_align == 0:
            return None
        aligned = [c for c in choices
                   if c >= self.matrix_align and c % self.matrix_align == 0]
        return min(aligned) if aligned else None

    def describe(self) -> str:
        """One-line human-readable profile summary (used by CLI output)."""
        fast = self.fast_mem_bytes / 2 ** 20
        fast_s = f"{fast:.0f} MiB" if fast >= 1 else \
            f"{self.fast_mem_bytes / 2 ** 10:.0f} KiB"
        return (f"{self.name}: {self.descriptor} — "
                f"{self.peak_flops / 1e12:.0f} TFLOP/s, "
                f"{self.hbm_bw / 1e9:.0f} GB/s HBM, "
                f"align {self.matrix_align}, "
                f"fast mem {fast_s}")


PlatformLike = Union[str, Platform, None]
