"""Registered hardware targets.

Four registered profiles:

* ``tpu_v5e``  — the reproduction's historical target; its ``hw`` dict is
  byte-for-byte the old ``roofline.analysis.HW_V5E`` module constant.
* ``tpu_v4``   — same ISA/idiom, different roofline ratios (more FLOPs,
  much more HBM bandwidth) so the memory/compute crossover moves.
* ``metal_m2`` — an Apple-Metal-class unified-memory GPU (the paper's
  second real platform): 8-wide ``simdgroup_matrix`` tiles, a 32 KiB
  threadgroup-memory working set (128-capped block dims), no discrete
  matrix unit (flat 2:1 matrix:vector ratio), MSL prompt idiom, and the
  §7.2 elements-per-thread trick as its reference-landing hint.
* ``gpu_sim``  — a simulated tensor-core-class GPU: 16-wide matrix tiles
  (vs the MXU's 128), a ~1 MiB shared-memory working set that makes the
  large TPU tile choices illegal, a 256 cap on single block dims, and a
  flatter matrix:vector peak ratio — so analysis rules and SPACES legality
  genuinely diverge from the TPUs, not just the constants.

New targets register with :func:`register_platform`; everything downstream
(candidates, analyzer, verifier, prompts, campaigns) picks them up by name.
The TPUs share a Mosaic ``compiler_params_fn``; ``metal_m2``/``gpu_sim``
deliberately have none, so ``kernels.ops.compiler_params_for`` hands their
``pallas_call`` no TPU compiler params.
"""
from __future__ import annotations

from typing import Dict, List

from repro.platforms import examples
from repro.platforms.base import Platform, PlatformLike

DEFAULT_PLATFORM = "tpu_v5e"

_REGISTRY: Dict[str, Platform] = {}


def register_platform(platform: Platform, *, overwrite: bool = False) -> Platform:
    """Add a hardware target to the registry (returns it for chaining).

    Raises ValueError on a duplicate name unless ``overwrite`` — tests use
    overwrite to shadow a profile, production code never should."""
    if not overwrite and platform.name in _REGISTRY:
        raise ValueError(f"platform {platform.name!r} already registered")
    _REGISTRY[platform.name] = platform
    return platform


def get_platform(name: str) -> Platform:
    """Registered platform by name; KeyError lists the available names."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown platform {name!r}; available: "
                       f"{', '.join(sorted(_REGISTRY))}") from None


def available_platforms() -> List[str]:
    """Sorted names of every registered platform (the CLI choices and the
    default platform set of the transfer matrix)."""
    return sorted(_REGISTRY)


def resolve_platform(platform: PlatformLike = None) -> Platform:
    """None -> default target; str -> registry lookup; Platform -> itself."""
    if platform is None:
        return _REGISTRY[DEFAULT_PLATFORM]
    if isinstance(platform, Platform):
        return platform
    return get_platform(platform)


def _tpu_compiler_params(**kwargs):
    from repro.kernels.ops import tpu_compiler_params
    return tpu_compiler_params(**kwargs)


# shared by every Pallas-TPU target (and, via the default platform, by
# prompts.render_synthesis when no constraints are passed)
TPU_CONSTRAINTS = ("Pay attention to VMEM working-set size (<= 128 MiB), "
                   "MXU tile alignment (128x128), and numerical stability "
                   "for large-magnitude inputs.")


register_platform(Platform(
    name="tpu_v5e",
    descriptor="Pallas TPU (v5e)",
    peak_flops=197e12,            # bf16 FLOP/s
    hbm_bw=819e9,                 # B/s
    link_bw=50e9,                 # ICI, B/s per link
    hbm_bytes=16e9,
    fast_mem_bytes=128 * 2 ** 20,  # VMEM
    matrix_align=128,             # MXU systolic array
    vector_align=8,               # sublanes
    max_tile=8192,
    vpu_ratio=8.0,
    oneshot_example=examples.VECTOR_ADD_PALLAS,
    constraints_note=TPU_CONSTRAINTS,
    compiler_params_fn=_tpu_compiler_params,
))

register_platform(Platform(
    name="tpu_v4",
    descriptor="Pallas TPU (v4)",
    peak_flops=275e12,
    hbm_bw=1228e9,
    link_bw=100e9,
    hbm_bytes=32e9,
    fast_mem_bytes=128 * 2 ** 20,
    matrix_align=128,
    vector_align=8,
    max_tile=8192,
    vpu_ratio=8.0,
    oneshot_example=examples.VECTOR_ADD_PALLAS,
    constraints_note=TPU_CONSTRAINTS,
    compiler_params_fn=_tpu_compiler_params,
))

register_platform(Platform(
    name="metal_m2",
    descriptor="Apple Metal GPU (M2-class)",
    # Unified-memory SoC: the GPU shares one LPDDR pool with the CPU, so
    # "HBM" bandwidth/capacity are the unified-memory figures and there is
    # no discrete-accelerator transfer link (link_bw is a PCIe-class floor
    # so the collective roofline term stays finite, not a real fabric).
    peak_flops=13.6e12,           # GPU ALU peak (fp16-rate), M2 Max-class
    hbm_bw=400e9,                 # unified LPDDR5 memory bandwidth
    link_bw=32e9,
    hbm_bytes=96e9,               # whole unified pool is GPU-addressable
    fast_mem_bytes=256 * 2 ** 10,  # 32 KiB threadgroup mem + register tiles
    matrix_align=8,               # simdgroup_matrix fragments are 8x8
    vector_align=32,              # SIMD-group width
    max_tile=128,                 # past this no tile triple fits on-chip
    vpu_ratio=2.0,                # no discrete matrix unit: simdgroup
                                  # matmul is ~2x the scalar ALU rate
    grid_step_overhead_s=1e-8,    # threadgroup dispatch
    seq_step_latency_s=4e-7,
    oneshot_example=examples.VECTOR_ADD_METAL,
    constraints_note="Pay attention to threadgroup-memory working-set size "
                     "(<= 32 KiB per threadgroup), simdgroup_matrix tile "
                     "alignment (8x8), SIMD-group width (32) execution, "
                     "elements-per-thread vectorization, and numerical "
                     "stability for large-magnitude inputs.",
    # The paper's §7.2 Metal case study: loop vectorization (8 elements per
    # thread) is the idiomatic landing for transferred elementwise kernels —
    # on this profile that is the block_rows axis. Rope tiles cap at the
    # threadgroup working-set ceiling (max_tile).
    reference_hints={"swish": {"block_rows": 8}, "rope": {"block_s": 128}},
))

register_platform(Platform(
    name="gpu_sim",
    descriptor="CUDA-class GPU (simulated)",
    peak_flops=312e12,            # tensor-core bf16
    hbm_bw=2039e9,                # HBM2e
    link_bw=600e9,                # NVLink
    hbm_bytes=80e9,
    fast_mem_bytes=2 ** 20,       # shared-memory tiling budget per kernel
    matrix_align=16,              # tensor-core fragment width
    vector_align=32,              # warp
    max_tile=256,                 # block dims past this never fit smem
    vpu_ratio=16.0,               # CUDA-core : tensor-core peak ratio
    grid_step_overhead_s=5e-9,    # fine-grained thread-block launch
    seq_step_latency_s=2e-7,
    oneshot_example=examples.VECTOR_ADD_CUDA,
    constraints_note="Pay attention to shared-memory working-set size "
                     "(<= 1 MiB per block), tensor-core fragment alignment "
                     "(16x16), warp-width (32) coalescing, and numerical "
                     "stability for large-magnitude inputs.",
    # Idiomatic GPU attention kernels are warp-specialized with wide query
    # tiles; any reference landing on this target biases block_q up-front.
    # Rope follows the same wide-tile bias up to the smem ceiling.
    reference_hints={"attention": {"block_q": 128},
                     "rope": {"block_s": 256}},
))
