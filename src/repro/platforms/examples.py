"""Per-platform one-shot examples embedded in every synthesis prompt.

Vector addition, exactly as the paper uses for CUDA (Appendix A) and Metal
(Appendix B) — here in each registered target's idiom. The TPU variant is a
Pallas kernel with explicit BlockSpec tiling plus the jit'd scheduling
wrapper; the GPU-class profile uses the paper's CUDA appendix-A example.
"""

VECTOR_ADD_PALLAS = '''\
import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import tpu_compiler_params


def _add_kernel(a_ref, b_ref, out_ref):
    # one (block_rows, block_lanes) VMEM tile per grid step
    out_ref[...] = a_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows", "block_lanes"))
def vector_add(a, b, *, block_rows=8, block_lanes=512):
    rows, lanes = a.shape
    spec = pl.BlockSpec((block_rows, block_lanes), lambda i, j: (i, j))
    return pl.pallas_call(
        _add_kernel,
        grid=(rows // block_rows, lanes // block_lanes),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
    )(a, b)


def candidate(a, b):
    return vector_add(a, b)
'''

# Reference implementation "from the other platform" (paper Appendix A) —
# also the one-shot example for the simulated GPU-class target.
VECTOR_ADD_CUDA = '''\
__global__ void elementwise_add_kernel(
    const float *a, const float *b, float *out, int size) {
  int idx = blockIdx.x * blockDim.x + threadIdx.x;
  if (idx < size) {
    out[idx] = a[idx] + b[idx];
  }
}
'''

# Metal Shading Language variant (paper Appendix B) — the one-shot example
# for the ``metal_m2`` target. Same parallel decomposition as the CUDA
# kernel; the launch idiom is a compute pipeline dispatch over a 1-D grid,
# with [[thread_position_in_grid]] playing blockIdx*blockDim+threadIdx.
VECTOR_ADD_METAL = '''\
#include <metal_stdlib>
using namespace metal;

kernel void elementwise_add_kernel(
    device const float *a    [[buffer(0)]],
    device const float *b    [[buffer(1)]],
    device float *out        [[buffer(2)]],
    constant uint &size      [[buffer(3)]],
    uint idx                 [[thread_position_in_grid]]) {
  if (idx < size) {
    out[idx] = a[idx] + b[idx];
  }
}
'''
