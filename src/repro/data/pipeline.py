"""Deterministic, sharded, resumable token pipeline.

Sources:
  * synthetic — counter-seeded PRNG tokens (repeatable across restarts);
  * memmap    — a flat uint16/uint32 token file, read in strided windows.

Determinism & fault tolerance: the pipeline is a pure function of
(seed, step, host_id); its entire mutable state is the integer ``step``,
which is stored in checkpoints. After restart (even onto a different host
count) batch b for step s is byte-identical.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    source: str = "synthetic"          # synthetic | memmap
    path: Optional[str] = None         # token file for memmap
    num_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        assert self.global_batch % self.num_hosts == 0


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.step = 0
        self._mm = None
        if cfg.source == "memmap":
            assert cfg.path, "memmap source needs a path"
            raw = np.memmap(cfg.path, dtype=np.uint16, mode="r")
            self._mm = raw

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step}

    def load_state_dict(self, state: Dict):
        self.step = int(state["step"])

    # -- batches --------------------------------------------------------------
    def _synthetic(self, step: int) -> np.ndarray:
        cfg = self.cfg
        local_b = cfg.global_batch // cfg.num_hosts
        rng = np.random.default_rng(
            np.uint64(cfg.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(65_537) + np.uint64(cfg.host_id))
        return rng.integers(0, cfg.vocab_size,
                            (local_b, cfg.seq_len + 1), dtype=np.int32)

    def _from_memmap(self, step: int) -> np.ndarray:
        cfg = self.cfg
        local_b = cfg.global_batch // cfg.num_hosts
        span = cfg.seq_len + 1
        n_windows = (len(self._mm) - 1) // span
        base = (step * cfg.global_batch + cfg.host_id * local_b) % n_windows
        rows = [(base + i) % n_windows for i in range(local_b)]
        out = np.stack([np.asarray(self._mm[r * span:(r + 1) * span],
                                   dtype=np.int32) for r in rows])
        return out % cfg.vocab_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        toks = (self._synthetic(step) if self._mm is None
                else self._from_memmap(step))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch
