"""Logical-axis sharding rules (DP / FSDP / TP / SP / EP / pod).

Models annotate parameters and activations with *logical* axis names
(('fsdp', 'tp'), ('batch', 'seq_sp', None), …). The launcher installs a
:class:`ShardingRules` mapping logical → physical mesh axes; outside a rules
context every constraint is a no-op, so smoke tests and the KForge loop run
unsharded without touching device state.

Physical mesh axes are ('pod', 'data', 'model') multi-pod or
('data', 'model') single-pod.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as PS

Physical = Union[None, str, Tuple[str, ...]]

# Default logical -> physical mapping (single-pod). `make_rules` extends the
# data-parallel axes with 'pod' for multi-pod meshes.
DEFAULT_LOGICAL: Dict[str, Physical] = {
    "batch": ("data",),       # DP over examples
    "fsdp": ("data",),        # ZeRO-3 param/optimizer shard
    "tp": "model",            # tensor parallel (heads / d_ff / vocab / experts)
    "seq_sp": "model",        # sequence-parallel residual stream
    "seq_kv": "model",        # flash-decode: KV cache sequence shard
    "expert": "model",        # expert parallel
    "layers": None,           # stacked-layer leading dim
}


@dataclasses.dataclass
class ShardingRules:
    mesh: Optional[jax.sharding.Mesh]
    logical: Dict[str, Physical]

    def axis_size(self, physical: Physical) -> int:
        if self.mesh is None or physical is None:
            return 1
        names = (physical,) if isinstance(physical, str) else physical
        size = 1
        for n in names:
            size *= self.mesh.shape.get(n, 1)
        return size


_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def set_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def make_rules(mesh: jax.sharding.Mesh,
               overrides: Optional[Dict[str, Physical]] = None) -> ShardingRules:
    logical = dict(DEFAULT_LOGICAL)
    if "pod" in mesh.shape:
        logical["batch"] = ("pod", "data")
        logical["fsdp"] = ("pod", "data")
    if overrides:
        logical.update(overrides)
    return ShardingRules(mesh=mesh, logical=logical)


def resolve_axes(axes: Sequence[Optional[str]],
                 rules: ShardingRules,
                 shape: Optional[Tuple[int, ...]] = None) -> PS:
    """Logical axes tuple -> PartitionSpec, dropping non-divisible entries."""
    out = []
    for i, ax in enumerate(axes):
        phys = rules.logical.get(ax) if ax else None
        if phys is not None and shape is not None:
            if shape[i] % rules.axis_size(phys) != 0:
                phys = None  # replicate instead of failing
        out.append(phys)
    return PS(*out)


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside a rules ctx."""
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = resolve_axes(axes, rules, tuple(x.shape))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(rules.mesh, spec))


def spec_tree(logical_tree, rules: ShardingRules, shape_tree=None):
    """Map a pytree of logical-axes tuples to NamedShardings.

    ``shape_tree`` (matching pytree of array-likes with .shape) enables the
    divisibility fallback.
    """
    if shape_tree is None:
        return jax.tree.map(
            lambda axes: jax.sharding.NamedSharding(
                rules.mesh, resolve_axes(axes, rules)),
            logical_tree, is_leaf=lambda t: isinstance(t, tuple))
    return jax.tree.map(
        lambda axes, arr: jax.sharding.NamedSharding(
            rules.mesh, resolve_axes(axes, rules, tuple(arr.shape))),
        logical_tree, shape_tree, is_leaf=lambda t: isinstance(t, tuple))
