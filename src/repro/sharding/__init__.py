from repro.sharding.rules import (  # noqa: F401
    ShardingRules, constrain, resolve_axes, set_rules, current_rules,
    make_rules, spec_tree,
)
