"""Flash-decode Pallas kernel: one query token vs. a long KV cache.

The query head group belonging to each KV head is processed together
(q reshaped to (B, KV, G, D)), so GQA costs one cache read per KV head.
The cache-sequence loop is the innermost grid dimension with online-softmax
accumulators in VMEM scratch. Positions >= lengths[b] are masked, so the
same kernel serves ragged batches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for

NEG_INF = -1e30
_LANES = 128


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, out_ref,
                   acc_ref, m_ref, l_ref, *, scale: float, block_k: int,
                   n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0, 0]
    k_start = ik * block_k

    @pl.when(k_start < length)
    def _update():
        q = q_ref[0, 0, :, :].astype(jnp.float32)      # (G, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0:1]
        l_prev = l_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = jnp.broadcast_to(
            alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True), l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)
        out_ref[0, 0, :, :] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k",
                                             "interpret", "platform"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = True,
                     platform: str | None = None) -> jax.Array:
    """q (B, 1, H, D); caches (B, S, KV, D); lengths (B,). Returns (B,1,H,D)."""
    b, one, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    assert one == 1 and h % kv == 0 and s % block_k == 0
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(b, kv, g, d)
    lengths2 = lengths.reshape(b, 1).astype(jnp.int32)
    n_k = s // block_k
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                          n_k=n_k),
        grid=(b, kv, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda ib, ih, ik: (ib, 0)),
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda ib, ih, ik: (ib, ik, ih, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda ib, ih, ik: (ib, ik, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda ib, ih, ik: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kv, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, d), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
            pltpu.VMEM((g, _LANES), jnp.float32),
        ],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths2, qg, k_cache, v_cache)
    return out.reshape(b, 1, h, d)
