"""Row softmax Pallas kernel (full row in VMEM, numerically stable)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _softmax_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    out_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "platform"))
def softmax(x: jax.Array, *, block_rows: int = 128,
            interpret: bool = True,
            platform: str | None = None) -> jax.Array:
    """x (T, D) -> softmax over D. T divisible by block_rows."""
    t, d = x.shape
    assert t % block_rows == 0
    return pl.pallas_call(
        _softmax_kernel,
        grid=(t // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)
