"""RWKV6 (Finch) WKV recurrence as a chunked Pallas TPU kernel.

State S ∈ R^{D×D} per (batch, head) lives in VMEM scratch and persists across
the (innermost, sequential) chunk grid dimension. Within a chunk the kernel
runs the exact recurrence step-by-step with rank-1 updates vectorized over
the D×D state tile — correct for arbitrary data-dependent decay w_t.

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, out_ref, s_ref, *,
                 chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0, :].astype(jnp.float32)                      # (D,)

    def step(t, _):
        rt = r_ref[0, t, 0, :].astype(jnp.float32)           # (D,)
        kt = k_ref[0, t, 0, :].astype(jnp.float32)
        vt = v_ref[0, t, 0, :].astype(jnp.float32)
        wt = w_ref[0, t, 0, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]                       # (D, D)
        s = s_ref[...]
        ot = jnp.sum(rt[:, None] * (s + u[:, None] * kv), axis=0)
        out_ref[0, t, 0, :] = ot.astype(out_ref.dtype)
        s_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "platform"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, *, chunk: int = 128,
         interpret: bool = True,
         platform: str | None = None) -> jax.Array:
    """r/k/v/w (B, T, H, D); u (H, D); T divisible by chunk. Returns (B,T,H,D) f32."""
    b, t, h, d = r.shape
    assert t % chunk == 0
    grid = (b, h, t // chunk)
    seq_spec = pl.BlockSpec((1, chunk, 1, d), lambda ib, ih, ic: (ib, ic, ih, 0))
    return pl.pallas_call(
        functools.partial(_wkv6_kernel, chunk=chunk),
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, d), lambda ib, ih, ic: (ih, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
