"""Public kernel API: jit'd wrappers around the Pallas kernels.

Every op takes ``impl`` ∈ {"auto", "pallas", "xla", "ref"}:

* ``pallas`` — the Pallas TPU kernel (interpret-mode automatically when not
  on a TPU backend, so the same call validates on CPU).
* ``xla``    — a memory-efficient pure-XLA implementation (chunked online
  softmax for attention, chunked log-sum-exp for the LM loss). This is the
  path the multi-pod dry-run lowers, and the "other platform" reference in
  KForge's cross-platform-transfer sense.
* ``ref``    — the naive oracle from :mod:`repro.kernels.ref`.
* ``auto``   — pallas on TPU, xla elsewhere.

Training gradients: :func:`attention` wraps the Pallas forward in a
``jax.custom_vjp`` whose backward recomputes via the chunked XLA
implementation (flash-style recompute; no S×S residuals are saved).

Every Pallas-backed op also takes ``platform`` (a registered platform name,
default ``None`` = the registry default target): backend compiler params
are built per platform via :func:`compiler_params_for`, so retargeting a
kernel to ``gpu_sim``/``metal_m2`` stops it from silently inheriting the
TPU Mosaic params.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.pallas import tpu as _pltpu


def tpu_compiler_params(**kwargs):
    """Version-compat constructor for Mosaic compiler params.

    The class is ``pltpu.TPUCompilerParams`` up to jax 0.4.x and
    ``pltpu.CompilerParams`` from 0.5 on; resolve whichever this jax
    provides. Defined before the kernel imports below so the kernel
    modules can import it without a circular-import failure.
    """
    cls = getattr(_pltpu, "CompilerParams", None) \
        or getattr(_pltpu, "TPUCompilerParams")
    return cls(**kwargs)


def compiler_params_for(platform=None, **kwargs):
    """Backend compiler params for ``pallas_call`` on one hardware target.

    ``platform`` is a registered platform name (or ``None`` for the default
    target). Targets with a compiler hook (the TPUs) get their real backend
    params (Mosaic ``dimension_semantics`` etc.); targets without one
    (``gpu_sim``, ``metal_m2``) get ``None`` so ``pallas_call`` receives no
    compiler params at all — instead of silently inheriting the TPU ones.

    Names (not :class:`~repro.platforms.Platform` instances) keep this
    usable as a ``jax.jit`` static argument, which is how the kernel
    modules thread it through.
    """
    from repro.platforms import resolve_platform
    p = resolve_platform(platform)
    if p.compiler_params_fn is None:
        return None
    return p.compiler_params(**kwargs)


def _platform_name(platform) -> Optional[str]:
    """Reduce a PlatformLike to the hashable name the kernels jit over."""
    if platform is None or isinstance(platform, str):
        return platform
    return platform.name


from repro.kernels import ref  # noqa: E402
from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2 as _mamba2
from repro.kernels import matmul as _matmul
from repro.kernels import rmsnorm as _rmsnorm
from repro.kernels import rope as _rope
from repro.kernels import rwkv6 as _rwkv6
from repro.kernels import softmax as _softmax
from repro.kernels import swiglu as _swiglu
from repro.kernels import swish as _swish
from repro.kernels import xent as _xent


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return not on_tpu()


def resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if on_tpu() else "xla"
    return impl


def _pad_rows(x: jax.Array, mult: int):
    t = x.shape[0]
    pad = (-t) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x, t


# ---------------------------------------------------------------------------
# Elementwise / norm ops
# ---------------------------------------------------------------------------


def rmsnorm(x, gamma, *, eps: float = 1e-5, impl: str = "auto",
            platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        x2, t = _pad_rows(x2, 256)
        out = _rmsnorm.rmsnorm(x2, gamma, eps=eps, interpret=_interpret(),
                               platform=_platform_name(platform))
        return out[:t].reshape(shape)
    return ref.rmsnorm(x, gamma, eps)


def swish(x, *, impl: str = "auto", platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        shape = x.shape
        x2 = x.reshape(-1)
        n = x2.shape[0]
        pad = (-n) % (8 * 512)
        x2 = jnp.pad(x2, (0, pad)).reshape(-1, 512)
        out = _swish.swish(x2, interpret=_interpret(),
                           platform=_platform_name(platform))
        return out.reshape(-1)[:n].reshape(shape)
    return ref.swish(x)


def softmax(x, *, impl: str = "auto", platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        x2, t = _pad_rows(x2, 128)
        out = _softmax.softmax(x2, interpret=_interpret(),
                               platform=_platform_name(platform))
        return out[:t].reshape(shape)
    return ref.softmax(x)


def swiglu_act(gate, up, *, impl: str = "auto", platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        shape = gate.shape
        g2 = gate.reshape(-1, shape[-1])
        u2 = up.reshape(-1, shape[-1])
        g2, t = _pad_rows(g2, 128)
        u2, _ = _pad_rows(u2, 128)
        f = shape[-1]
        bc = 512 if f % 512 == 0 else f
        out = _swiglu.swiglu_act(g2, u2, block_cols=bc, interpret=_interpret(),
                                 platform=_platform_name(platform))
        return out[:t].reshape(shape)
    return ref.swish(gate) * up


def matmul(a, b, *, impl: str = "auto", block_m=128, block_n=128,
           block_k=128, platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        m, k = a.shape
        _, n = b.shape
        pm, pn, pk = (-m) % block_m, (-n) % block_n, (-k) % block_k
        a2 = jnp.pad(a, ((0, pm), (0, pk)))
        b2 = jnp.pad(b, ((0, pk), (0, pn)))
        out = _matmul.matmul(a2, b2, block_m=block_m, block_n=block_n,
                             block_k=block_k, interpret=_interpret(),
                             platform=_platform_name(platform))
        return out[:m, :n]
    return ref.matmul(a, b)


def rope(x, positions, *, theta: float = 10_000.0, impl: str = "auto",
         platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas" and x.shape[1] % 256 == 0:
        return _rope.rope(x, positions.astype(jnp.int32), theta=theta,
                          interpret=_interpret(),
                          platform=_platform_name(platform))
    return ref.rope(x, positions, theta)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def xla_full_attention(q, k, v, *, causal: bool = True,
                       scale: Optional[float] = None) -> jax.Array:
    """Materialized (quadratic) attention in pure XLA, f32 softmax.

    Best choice for TRAINING at moderate sequence lengths: a single MXU dot
    with heads TP-sharded, no scan carries saved for backward (the enclosing
    layer remat recomputes it). Peak transient = (B, H, Sq, Sk) f32 / TP."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    kx = ref._expand_kv(k, h)
    vx = ref._expand_kv(v, h)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        q_pos = jnp.arange(sq) + (sk - sq)
        mask = jnp.arange(sk)[None, :] <= q_pos[:, None]
        s = jnp.where(mask[None, None], s, _fa.NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), vx,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def xla_chunked_attention(q, k, v, *, causal: bool = True,
                          scale: Optional[float] = None,
                          chunk: int = 1024) -> jax.Array:
    """Memory-efficient attention in pure XLA: lax.scan over KV chunks with
    online softmax. Peak live logits: (B, H, Sq, chunk); f32 accumulators.

    GQA expands KV heads per streamed chunk (keeps the head axis intact so
    TP sharding propagates without involuntary resharding — a (KV, G)
    reshape of an H-sharded axis forces SPMD rematerialization)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    g = h // kv
    scale = scale if scale is not None else d ** -0.5
    chunk = min(chunk, sk)
    while sk % chunk:  # largest divisor of sk <= requested chunk
        chunk -= 1
    n_chunks = sk // chunk

    qf = q.astype(jnp.float32) * scale                      # (B, Sq, H, D)
    q_pos = jnp.arange(sq) + (sk - sq)

    def body(carry, ic):
        m_prev, l_prev, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ic * chunk, chunk, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ic * chunk, chunk, axis=1)
        if g > 1:
            ks = jnp.repeat(ks, g, axis=2)
            vs = jnp.repeat(vs, g, axis=2)
        s = jnp.einsum("bqhd,bchd->bhqc", qf, ks.astype(jnp.float32))
        if causal:
            k_pos = ic * chunk + jnp.arange(chunk)
            mask = k_pos[None, :] <= q_pos[:, None]          # (Sq, chunk)
            s = jnp.where(mask[None, None], s, _fa.NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        if causal:
            p = jnp.where(mask[None, None], p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqc,bchd->bhqd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((b, h, sq), _fa.NEG_INF, jnp.float32),
            jnp.zeros((b, h, sq), jnp.float32),
            jnp.zeros((b, h, sq, d), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    l = jnp.where(l == 0.0, 1.0, l)
    out = acc / l[..., None]
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _pallas_attention(q, k, v, causal, scale, platform):
    return _fa.flash_attention(q, k, v, causal=causal, scale=scale,
                               interpret=_interpret(), platform=platform)


def _pallas_attention_fwd(q, k, v, causal, scale, platform):
    return _pallas_attention(q, k, v, causal, scale, platform), (q, k, v)


def _pallas_attention_bwd(causal, scale, platform, res, g):
    q, k, v = res
    # Flash-style recompute backward via the chunked XLA implementation.
    _, vjp = jax.vjp(
        lambda q, k, v: xla_chunked_attention(q, k, v, causal=causal,
                                              scale=scale), q, k, v)
    return vjp(g)


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def recompute_vjp(fwd_fn, ref_fn):
    """Generalize the ``_pallas_attention`` machinery: wrap a Pallas-backed
    forward in ``jax.custom_vjp`` whose backward recomputes through a
    pure-XLA reference. ``pallas_call`` has no VJP rule, so this is what
    makes a Pallas candidate differentiable for ``direction="fwd_bwd"``
    verification: forward runs the kernel under test, backward is
    flash-style recompute — ``jax.vjp`` over ``ref_fn`` at the saved
    inputs, pulled back through the incoming cotangent. ``ref_fn`` must be
    mathematically equivalent to ``fwd_fn`` on the same positional args."""
    @jax.custom_vjp
    def wrapped(*args):
        return fwd_fn(*args)

    def fwd(*args):
        return fwd_fn(*args), args

    def bwd(res, g):
        _, vjp = jax.vjp(ref_fn, *res)
        return vjp(g)

    wrapped.defvjp(fwd, bwd)
    return wrapped


# Self-attention at or below this Sq·Sk switches to the materialized path
# under impl="xla" (transient (B,H,Sq,Sk) f32 / TP is cheap; no scan carries
# are saved for backward). Longer sequences stream KV chunks.
FULL_ATTN_MAX_SEQ = 8192
TRAIN_ATTN = "chunked"  # full | chunked (xla self-attention strategy)


def attention(q, k, v, *, causal: bool = True, scale: Optional[float] = None,
              impl: str = "auto", chunk: int = 1024, platform=None):
    """q (B,Sq,H,D), k/v (B,Sk,KV,D) -> (B,Sq,H,D). Differentiable."""
    impl = resolve_impl(impl)
    if impl == "pallas":
        d = q.shape[-1]
        return _pallas_attention(q, k, v, causal,
                                 scale if scale is not None else d ** -0.5,
                                 _platform_name(platform))
    if impl == "xla_full":
        return xla_full_attention(q, k, v, causal=causal, scale=scale)
    if impl == "xla_chunked":
        return xla_chunked_attention(q, k, v, causal=causal, scale=scale,
                                     chunk=chunk)
    if impl == "xla":
        if q.shape[1] == 1 or (TRAIN_ATTN == "full" and q.shape[1] * k.shape[1]
                               <= FULL_ATTN_MAX_SEQ ** 2 // 16):
            return xla_full_attention(q, k, v, causal=causal, scale=scale)
        return xla_chunked_attention(q, k, v, causal=causal, scale=scale,
                                     chunk=chunk)
    return ref.attention(q, k, v, causal=causal, scale=scale)


def decode_attention(q, k_cache, v_cache, lengths, *,
                     scale: Optional[float] = None, impl: str = "auto",
                     platform=None):
    """One-token attention vs a KV cache. q (B,1,H,D), caches (B,S,KV,D)."""
    impl = resolve_impl(impl)
    if impl == "pallas" and k_cache.shape[1] % 512 == 0:
        return _dec.decode_attention(q, k_cache, v_cache, lengths,
                                     scale=scale, interpret=_interpret(),
                                     platform=_platform_name(platform))
    return ref.decode_attention(q, k_cache, v_cache, lengths, scale=scale)


# ---------------------------------------------------------------------------
# Recurrences
# ---------------------------------------------------------------------------


def wkv6(r, k, v, w, u, *, impl: str = "auto", chunk: int = 128,
         platform=None):
    """RWKV6 over a full sequence; returns (B,T,H,D) f32 outputs only."""
    impl = resolve_impl(impl)
    t = r.shape[1]
    if impl == "pallas" and t % chunk == 0:
        return _rwkv6.wkv6(r, k, v, w, u, chunk=chunk, interpret=_interpret(),
                           platform=_platform_name(platform))
    out, _ = ref.wkv6(r, k, v, w, u)
    return out


def ssd(x, a, b, c, *, impl: str = "auto", chunk: int = 256, platform=None):
    impl = resolve_impl(impl)
    t = x.shape[1]
    if impl == "pallas" and t % chunk == 0:
        return _mamba2.ssd(x, a, b, c, chunk=chunk, interpret=_interpret(),
                           platform=_platform_name(platform))
    y, _ = ref.ssd(x, a, b, c)
    return y


def wkv6_matrix(r, k, v, w, u, *, chunk: int = 64, state=None):
    """RWKV6 WKV in chunked matrix form (per-CHANNEL data-dependent decay).

    Derivation (S_t = diag(w_t) S_{t-1} + k_t v_tᵀ,
                o_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)):
      intra:  o_t = Σ_{s<t} [Σ_d r_t·k_s·exp(L_{t-1}-L_s)]_d v_s
                    + (r_t·u·k_t) v_t
      inter:  o_t += (r_t ⊙ exp(L_{t-1}-L_{-1}))ᵀ S_prev
      state:  S    = diag(exp(L_c-L_{-1})) S_prev + Σ_s (exp(L_c-L_s)⊙k_s) v_sᵀ
    with L_t = chunk-local cumulative log-decay (inclusive). All exponents
    are differences with t ≥ s ⇒ ≤ 0: numerically stable without the
    overflowing 1/decay factorization. The (c, c, D) decay tensor is
    materialized per chunk (transient), traded for ~chunk× fewer sequential
    steps than the token recurrence.

    r/k/v/w (B,T,H,D); u (H,D). Returns (out (B,T,H,D) f32, state (B,H,D,D)).
    """
    bsz, t, h, d = r.shape
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    nc = t // chunk
    f32 = jnp.float32
    rs = lambda z: z.astype(f32).reshape(bsz, nc, chunk, h, d)
    rc, kc, vc, wc = rs(r), rs(k), rs(v), rs(w)
    uf = u.astype(f32)
    logw = jnp.log(jnp.maximum(wc, 1e-20))
    cum = jnp.cumsum(logw, axis=2)                          # L_t, inclusive
    cum_prev = cum - logw                                   # L_{t-1}

    # intra-chunk: dec[t,s] = exp(L_{t-1} - L_s) for s <= t-1
    diff = cum_prev[:, :, :, None, :, :] - cum[:, :, None, :, :, :]
    # diff: (B,nc,t,s,H,D)
    mask = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
    dec = jnp.where(mask[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bnthd,bnshd,bntshd->bntsh", rc, kc, dec)
    out = jnp.einsum("bntsh,bnshd->bnthd", scores, vc)
    # diagonal bonus term
    diag = jnp.einsum("bnthd,hd,bnthd->bnth", rc, uf, kc)
    out = out + diag[..., None] * vc

    # inter-chunk
    dec_out = jnp.exp(cum_prev)                             # exp(L_{t-1}-L_{-1})
    dec_in = jnp.exp(cum[:, :, -1:, :, :] - cum)            # exp(L_c - L_s)
    chunk_state = jnp.einsum("bnshd,bnshe->bnhde",
                             dec_in * kc, vc)               # (B,nc,H,D,D)
    w_total = jnp.exp(cum[:, :, -1])                        # (B,nc,H,D)

    if state is None:
        state = jnp.zeros((bsz, h, d, d), f32)

    def body(s_prev, inp):
        cs, wt, rr, dout = inp
        y_in = jnp.einsum("bthd,bhde->bthe", rr * dout, s_prev)
        s_new = wt[:, :, :, None] * s_prev + cs
        return s_new, y_in

    xs = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(w_total, 1, 0),
          jnp.moveaxis(rc, 1, 0), jnp.moveaxis(dec_out, 1, 0))
    state, y_inter = jax.lax.scan(body, state, xs)
    out = out + jnp.moveaxis(y_inter, 0, 1)
    return out.reshape(bsz, t, h, d), state


def ssd_matrix(x, a, b, c, *, chunk: int = 256, state=None):
    """Mamba2 SSD in matrix (chunk-parallel) form — the actual SSD algorithm.

    Replaces the token-by-token recurrence (4096 sequential (B,H,P,N) state
    updates per layer — hopelessly memory-bound) with per-chunk MXU matmuls:

      intra:  y[t] += Σ_{s<=t} exp(cum[t]-cum[s]) (c_t·b_s) x_s
      inter:  y[t] += exp(cum[t]) · S_prev c_t
      state:  S     = exp(cum[-1]) S_prev + Σ_s exp(cum[-1]-cum[s]) x_s⊗b_s

    All decay factors are products of a_t ∈ (0,1) ⇒ ≤ 1: numerically stable.
    x (B,T,H,P); a (B,T,H); b/c (B,T,H,N). Returns (y (B,T,H,P) f32, S).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    shared_bc = b.ndim == 3  # (B,T,N): B/C shared across heads (mamba2
    # ngroups=1) — §Perf iteration B2: never materialize the (B,T,H,N)
    # broadcast (1.9 GB/layer/tensor at zamba2 scale).
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    nc = t // chunk
    f32 = jnp.float32
    xc = x.astype(f32).reshape(bsz, nc, chunk, h, p)
    ac = a.astype(f32).reshape(bsz, nc, chunk, h)
    if shared_bc:
        bc_ = b.astype(f32).reshape(bsz, nc, chunk, n)
        cc_ = c.astype(f32).reshape(bsz, nc, chunk, n)
    else:
        bc_ = b.astype(f32).reshape(bsz, nc, chunk, h, n)
        cc_ = c.astype(f32).reshape(bsz, nc, chunk, h, n)
    cum = jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-20)), axis=2)  # (B,nc,c,H)

    # decay ratio matrix L[t,s] = exp(cum[t] - cum[s]) for s <= t (else 0)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,t,s,H)
    mask = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
    dec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    if shared_bc:
        g_ts = jnp.einsum("bnti,bnsi->bnts", cc_, bc_)         # (B,nc,t,s)
        y_intra = jnp.einsum("bnts,bntsh,bnshp->bnthp", g_ts, dec, xc)
    else:
        scores = jnp.einsum("bnthi,bnshi->bntsh", cc_, bc_) * dec
        y_intra = jnp.einsum("bntsh,bnshp->bnthp", scores, xc)

    # inter-chunk: sequential scan over nc chunks (state carry)
    dec_out = jnp.exp(cum)                                      # (B,nc,c,H)
    dec_in = jnp.exp(cum[:, :, -1:, :] - cum)                   # (B,nc,c,H)
    if shared_bc:
        chunk_state = jnp.einsum("bnsh,bnshp,bnsi->bnhpi", dec_in, xc, bc_)
    else:
        chunk_state = jnp.einsum("bnsh,bnshp,bnshi->bnhpi", dec_in, xc, bc_)
    a_total = jnp.exp(cum[:, :, -1, :])                         # (B,nc,H)

    if state is None:
        state = jnp.zeros((bsz, h, p, n), f32)

    def body(s_prev, inp):
        cs, at, co, dout = inp  # chunk_state, a_total, c-block, dec_out
        if shared_bc:
            y_in = jnp.einsum("bhpi,bti,bth->bthp", s_prev, co, dout)
        else:
            y_in = jnp.einsum("bhpi,bthi,bth->bthp", s_prev, co, dout)
        s_new = at[:, :, None, None] * s_prev + cs
        return s_new, y_in

    xs = (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(a_total, 1, 0),
          jnp.moveaxis(cc_, 1, 0), jnp.moveaxis(dec_out, 1, 0))
    state, y_inter = jax.lax.scan(body, state, xs)
    y = y_intra + jnp.moveaxis(y_inter, 0, 1)
    return y.reshape(bsz, t, h, p), state


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def xla_chunked_xent(logits_fn, x, labels, vocab_w, *, chunk_s: int = 512):
    """Chunked LM loss: scans over SEQUENCE chunks computing logits + CE per
    chunk so (B, S, V) fp32 logits are never resident.

    Chunking over the sequence axis (not flattened tokens) keeps the batch
    dimension sharded under pjit — a flattened-token scan makes every chunk
    live on one data shard and the dx accumulator replicated.

    logits_fn(x_chunk (B, c, D), vocab_w) -> (B, c, V) logits.
    x (B, S, D); labels (B, S) with -1 = ignore.
    Returns (summed loss, valid count).
    """
    b, s, _ = x.shape
    chunk_s = min(chunk_s, s)
    while s % chunk_s:
        chunk_s -= 1
    n = s // chunk_s

    # remat: without it the scan stacks every chunk's logits as backward
    # residuals — O(S·V) fp32, exactly what chunking must avoid.
    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(acc, ic):
        total, count = acc
        xs = jax.lax.dynamic_slice_in_dim(x, ic * chunk_s, chunk_s, axis=1)
        ls = jax.lax.dynamic_slice_in_dim(labels, ic * chunk_s, chunk_s,
                                          axis=1)
        logits = logits_fn(xs, vocab_w)
        valid = ls >= 0
        lf = logits.reshape(-1, logits.shape[-1])
        loss = ref.softmax_xent(lf, jnp.maximum(ls.reshape(-1), 0))
        loss = jnp.where(valid.reshape(-1), loss, 0.0)
        return (total + jnp.sum(loss),
                count + jnp.sum(valid.astype(jnp.float32))), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        jnp.arange(n))
    return total, count


def softmax_xent(logits, labels, *, impl: str = "auto", platform=None):
    impl = resolve_impl(impl)
    if impl == "pallas":
        t, v = logits.shape
        if t % 128 == 0 and v % 2048 == 0:
            return _xent.softmax_xent(logits, labels, interpret=_interpret(),
                                      platform=_platform_name(platform))
    return ref.softmax_xent(logits, labels)
