"""Pallas TPU kernels for the perf-critical hot spots, plus jnp oracles.

Layout (per the repo convention):
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrappers (impl switching, padding, custom_vjp)
  ref.py    — pure-jnp oracles used by tests and by KForge as the
              cross-platform reference implementations
"""
from repro.kernels import ops, ref  # noqa: F401
