"""Fused SwiGLU activation Pallas kernel: silu(gate) * up in one VMEM pass.

(The surrounding matmuls use kernels/matmul.py or XLA; fusing the two
elementwise streams halves HBM traffic for the activation stage.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _swiglu_kernel(g_ref, u_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    out_ref[...] = (g * (1.0 / (1.0 + jnp.exp(-g))) * u).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols",
                                             "interpret", "platform"))
def swiglu_act(gate: jax.Array, up: jax.Array, *, block_rows: int = 128,
               block_cols: int = 512, interpret: bool = True,
               platform: str | None = None) -> jax.Array:
    """gate/up (T, F) -> silu(gate)*up, tile-divisible."""
    t, f = gate.shape
    assert gate.shape == up.shape
    assert t % block_rows == 0 and f % block_cols == 0
    spec = pl.BlockSpec((block_rows, block_cols), lambda i, j: (i, j))
    return pl.pallas_call(
        _swiglu_kernel,
        grid=(t // block_rows, f // block_cols),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(gate.shape, gate.dtype),
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(gate, up)
