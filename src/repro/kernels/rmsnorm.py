"""RMSNorm Pallas kernel: row-blocked, full feature dim in VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _rmsnorm_kernel(x_ref, g_ref, out_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    out_ref[...] = (x * inv * g_ref[...].astype(jnp.float32)).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "eps",
                                             "interpret", "platform"))
def rmsnorm(x: jax.Array, gamma: jax.Array, *, eps: float = 1e-5,
            block_rows: int = 256, interpret: bool = True,
            platform: str | None = None) -> jax.Array:
    """x (T, D), gamma (D,). T divisible by block_rows (wrapper pads)."""
    t, d = x.shape
    assert t % block_rows == 0, (t, block_rows)
    g2 = gamma.reshape(1, d)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(t // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, g2)
