"""Fused softmax cross-entropy Pallas kernel.

Streams vocab tiles through VMEM with an online logsumexp — the (T, V)
logit matrix is never resident, which is what makes 100k+ vocabularies
(deepseek/moonshot/qwen) trainable without materializing fp32 logits.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for

NEG_INF = -1e30
_LANES = 128


def _xent_kernel(logits_ref, labels_ref, loss_ref, m_ref, l_ref, g_ref, *,
                 block_v: int, n_v: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        g_ref[...] = jnp.zeros_like(g_ref)

    x = logits_ref[...].astype(jnp.float32)                 # (bt, bv)
    labels = labels_ref[...]                                # (bt,)
    vocab_ids = iv * block_v + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    m_prev = m_ref[:, 0:1]
    l_prev = l_ref[:, 0:1]
    m_new = jnp.maximum(m_prev, jnp.max(x, axis=-1, keepdims=True))
    p = jnp.exp(x - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = jnp.broadcast_to(alpha * l_prev + jnp.sum(p, -1, keepdims=True),
                                  l_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    hit = (vocab_ids == labels[:, None])
    g_ref[...] += jnp.broadcast_to(
        jnp.sum(jnp.where(hit, x, 0.0), axis=-1, keepdims=True), g_ref.shape)

    @pl.when(iv == n_v - 1)
    def _finish():
        lse = m_ref[:, 0] + jnp.log(l_ref[:, 0])
        loss_ref[...] = (lse - g_ref[:, 0]).astype(loss_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_v",
                                             "interpret", "platform"))
def softmax_xent(logits: jax.Array, labels: jax.Array, *, block_t: int = 128,
                 block_v: int = 2048, interpret: bool = True,
                 platform: str | None = None) -> jax.Array:
    """logits (T, V), labels (T,) int32 -> per-token loss (T,) f32."""
    t, v = logits.shape
    assert t % block_t == 0 and v % block_v == 0
    n_v = v // block_v
    return pl.pallas_call(
        functools.partial(_xent_kernel, block_v=block_v, n_v=n_v),
        grid=(t // block_t, n_v),
        in_specs=[
            pl.BlockSpec((block_t, block_v), lambda it, iv: (it, iv)),
            pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        ],
        out_specs=pl.BlockSpec((block_t,), lambda it, iv: (it,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_t, _LANES), jnp.float32),
            pltpu.VMEM((block_t, _LANES), jnp.float32),
            pltpu.VMEM((block_t, _LANES), jnp.float32),
        ],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(logits, labels)
