"""Causal GQA flash attention (forward) as a Pallas TPU kernel.

TPU adaptation of FlashAttention: online softmax over KV tiles streamed
HBM→VMEM, f32 accumulators in VMEM scratch, MXU-aligned (block_q × head_dim)
and (block_k × head_dim) tiles. The KV tile loop is the innermost
(sequential) grid dimension so scratch accumulators persist across it.

GQA is handled in the BlockSpec index maps: query head h reads KV head
h // (H // KV) — no jnp.repeat materialization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k: int, seq_k: int, seq_q: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q + (seq_k - seq_q)  # causal offset for Sq < Sk
    k_start = ik * block_k

    def _update():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)      # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            mask = kpos <= qpos
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0:1]                          # (bq, 1)
        l_prev = l_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        if causal:
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = jnp.broadcast_to(alpha * l_prev +
                                      jnp.sum(p, axis=-1, keepdims=True),
                                      l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if causal:
        # Skip KV tiles entirely above the diagonal.
        pl.when(k_start <= q_start + block_q - 1)(_update)
    else:
        _update()

    @pl.when(ik == n_k - 1)
    def _finish():
        l = l_ref[:, 0:1]
        l = jnp.where(l == 0.0, 1.0, l)
        out_ref[0, :, 0, :] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "block_q",
                                             "block_k", "interpret", "platform"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True,
                    platform: str | None = None) -> jax.Array:
    """q (B, Sq, H, D); k/v (B, Sk, KV, D); KV divides H. Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    assert h % kv == 0 and sq % block_q == 0 and sk % block_k == 0
    group = h // kv
    scale = scale if scale is not None else d ** -0.5
    n_k = sk // block_k
    grid = (b, h, sq // block_q, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k=n_k, seq_k=sk, seq_q=sq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d),
                         lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
            pl.BlockSpec((1, block_k, 1, d),
                         lambda ib, ih, iq, ik: (ib, ik, ih // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, d),
                               lambda ib, ih, iq, ik: (ib, iq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
        ],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
