"""Mamba2 SSD recurrence as a chunked Pallas TPU kernel.

Per (batch, head): state H ∈ R^{P×N} persists in VMEM scratch across the
sequential chunk grid dimension:

    H_t = a_t · H_{t-1} + x_t ⊗ b_t
    y_t = H_t c_t
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    def step(t, _):
        xt = x_ref[0, t, 0, :].astype(jnp.float32)      # (P,)
        at = a_ref[0, t, 0].astype(jnp.float32)         # scalar
        bt = b_ref[0, t, 0, :].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t, 0, :].astype(jnp.float32)      # (N,)
        s = at * s_ref[...] + xt[:, None] * bt[None, :]
        s_ref[...] = s
        y_ref[0, t, 0, :] = jnp.sum(s * ct[None, :], axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret", "platform"))
def ssd(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array, *,
        chunk: int = 256, interpret: bool = True,
        platform: str | None = None) -> jax.Array:
    """x (B,T,H,P); a (B,T,H); b/c (B,T,H,N). Returns y (B,T,H,P) f32."""
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    assert t % chunk == 0
    grid = (bsz, h, t // chunk)
    return pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1, n), lambda ib, ih, ic: (ib, ic, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, h, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, b, c)
