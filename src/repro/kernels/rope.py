"""Rotary position embedding Pallas kernel (angles computed in-kernel)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _rope_kernel(x_ref, pos_ref, out_ref, *, theta: float, half: int):
    x = x_ref[0, :, 0, :].astype(jnp.float32)             # (bs, d)
    pos = pos_ref[0, :].astype(jnp.float32)               # (bs,)
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freq[None, :]                    # (bs, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[:, :half], x[:, half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out_ref[0, :, 0, :] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("theta", "block_s",
                                             "interpret", "platform"))
def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10_000.0,
         block_s: int = 256, interpret: bool = True,
         platform: str | None = None) -> jax.Array:
    """x (B, S, H, D); positions (B, S) int32. S divisible by block_s."""
    b, s, h, d = x.shape
    assert s % block_s == 0 and d % 2 == 0
    return pl.pallas_call(
        functools.partial(_rope_kernel, theta=theta, half=d // 2),
        grid=(b, h, s // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, 1, d), lambda ib, ih, isq: (ib, isq, ih, 0)),
            pl.BlockSpec((1, block_s), lambda ib, ih, isq: (ib, isq)),
        ],
        out_specs=pl.BlockSpec((1, block_s, 1, d),
                               lambda ib, ih, isq: (ib, isq, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(x, positions)
