"""Swish/SiLU Pallas kernel.

TPU analogue of the paper's §7.2 Metal case study: instead of Metal's
"8 elements per thread" loop vectorization, the VPU-native version processes
an (block_rows, block_lanes) VMEM tile per grid step — sublane×lane
vectorization with a single bounds decision per tile (tiles are pre-padded by
the wrapper), and exp via the hardware transcendental unit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _swish_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = (x * (1.0 / (1.0 + jnp.exp(-x)))).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_lanes",
                                             "interpret", "platform"))
def swish(x: jax.Array, *, block_rows: int = 8, block_lanes: int = 512,
          interpret: bool = True,
          platform: str | None = None) -> jax.Array:
    """Elementwise swish on a 2D array (rows, lanes), tile-divisible."""
    r, l = x.shape
    assert r % block_rows == 0 and l % block_lanes == 0, (x.shape,)
    return pl.pallas_call(
        _swish_kernel,
        grid=(r // block_rows, l // block_lanes),
        in_specs=[pl.BlockSpec((block_rows, block_lanes), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_rows, block_lanes), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
