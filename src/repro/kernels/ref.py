"""Pure-jnp oracle implementations for every Pallas kernel in this package.

These are the "reference implementations" in KForge's sense: the known-correct
program on the *other platform* (XLA) that (a) grades candidate kernels in the
verification stage and (b) is injected into the generation agent's prompt for
cross-platform knowledge transfer (paper §6.2).

Everything here favours clarity over speed. Shapes follow the conventions:
  activations:  (B, S, D)        tokens
  attention:    q (B, S, H, Dh), k/v (B, S, KV, Dh)
  wkv/ssd:      per-head states, see docstrings
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# L1 primitives
# ---------------------------------------------------------------------------


def swish(x: jax.Array) -> jax.Array:
    """Swish/SiLU: x * sigmoid(x). (Paper case study §7.2.)"""
    return x * jax.nn.sigmoid(x)


def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    m = jnp.max(x, axis=axis, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=axis, keepdims=True)


def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    return (x.astype(jnp.float32) * inv * gamma.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma + beta).astype(x.dtype)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: (silu(x@Wg) * (x@Wu)) @ Wd."""
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    h = (swish(g) * u).astype(x.dtype)
    return jnp.dot(h, w_down, preferred_element_type=jnp.float32).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10_000.0) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freq  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,S,KV,D) -> (B,S,H,D) by repeating each KV head H/KV times."""
    b, s, kv, d = k.shape
    rep = num_heads // kv
    return jnp.repeat(k, rep, axis=2) if rep > 1 else k


def attention(q, k, v, *, causal: bool = True, scale: float | None = None,
              bias=None) -> jax.Array:
    """Naive full attention oracle. q:(B,Sq,H,D) k/v:(B,Sk,KV,D)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias
    if causal:
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        ki = jnp.arange(sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    p = softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, lengths=None, *, scale=None):
    """Single-token decode oracle. q:(B,1,H,D), caches:(B,S,KV,D).

    ``lengths`` (B,) masks cache positions >= length.
    """
    b, _, h, d = q.shape
    s = k_cache.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k = _expand_kv(k_cache, h)
    v = _expand_kv(v_cache, h)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if lengths is not None:
        mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    p = softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) — data-dependent decay linear attention
# ---------------------------------------------------------------------------


def wkv6(r, k, v, w, u, state=None):
    """RWKV6 WKV recurrence, oracle via lax.scan over time.

    Per head with head_dim D, state S in R^{D x D} (k-dim x v-dim):
        o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Args:  r,k,v,w: (B, T, H, D); u: (H, D).  w is decay in (0,1).
           state: optional (B, H, D, D) initial state.
    Returns: (out (B,T,H,D), final state (B,H,D,D)).
    """
    b, t, h, d = r.shape
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    if state is None:
        state = jnp.zeros((b, h, d, d), f32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,D) each
        kv = kt[..., :, None] * vt[..., None, :]          # (B,H,D,D)
        ot = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, ot

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, w))  # (T,B,H,D)
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(jnp.float32), state


def wkv6_decode(r, k, v, w, u, state):
    """One-token WKV6 step. r,k,v,w: (B,H,D); state: (B,H,D,D)."""
    f32 = jnp.float32
    r, k, v, w, u = (x.astype(f32) for x in (r, k, v, w, u))
    kv = k[..., :, None] * v[..., None, :]
    out = jnp.einsum("bhi,bhij->bhj", r, state + u[None, :, :, None] * kv)
    new_state = w[..., :, None] * state + kv
    return out, new_state


# ---------------------------------------------------------------------------
# Mamba2 SSD (state-space dual) scan
# ---------------------------------------------------------------------------


def ssd(x, a, b, c, state=None):
    """Mamba2 SSD recurrence, oracle via lax.scan.

    Per head with head_dim P and state_dim N:
        H_t = a_t * H_{t-1} + x_t ⊗ b_t      (H in R^{P x N})
        y_t = H_t c_t
    Args: x (B,T,H,P); a (B,T,H) decay in (0,1); b,c (B,T,H,N).
          state optional (B,H,P,N).
    Returns (y (B,T,H,P), final state).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    f32 = jnp.float32
    x, a, b, c = (z.astype(f32) for z in (x, a, b, c))
    if state is None:
        state = jnp.zeros((bsz, h, p, n), f32)

    def step(s, inp):
        xt, at, bt, ct = inp
        s = at[..., None, None] * s + xt[..., :, None] * bt[..., None, :]
        yt = jnp.einsum("bhpn,bhn->bhp", s, ct)
        return s, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(a, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    state, y = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(y, 0, 1).astype(jnp.float32), state


def ssd_decode(x, a, b, c, state):
    """One-token SSD step. x (B,H,P); a (B,H); b,c (B,H,N); state (B,H,P,N)."""
    f32 = jnp.float32
    x, a, b, c = (z.astype(f32) for z in (x, a, b, c))
    state = a[..., None, None] * state + x[..., :, None] * b[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, c)
    return y, state


# ---------------------------------------------------------------------------
# MoE routing
# ---------------------------------------------------------------------------


def topk_router(logits: jax.Array, k: int):
    """Top-k softmax router. logits (..., E) -> (weights (...,k), idx (...,k)).

    Weights renormalized over the selected k experts.
    """
    vals, idx = jax.lax.top_k(logits.astype(jnp.float32), k)
    w = softmax(vals, axis=-1)
    return w, idx


def moe_mlp(x, router_w, experts_wg, experts_wu, experts_wd, top_k: int):
    """Dense-dispatch MoE oracle: every expert computed, gathered by weight.

    x (T, D); router_w (D, E); experts_* (E, D, F)/(E, F, D).
    O(T·E·D·F) — oracle only; the real path uses capacity dispatch.
    """
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T,E)
    w, idx = topk_router(logits, top_k)
    e = router_w.shape[-1]
    gate = jnp.zeros((x.shape[0], e), jnp.float32)
    gate = gate.at[jnp.arange(x.shape[0])[:, None], idx].add(w)     # (T,E)
    h = jnp.einsum("td,edf->tef", x.astype(jnp.float32),
                   experts_wg.astype(jnp.float32))
    u = jnp.einsum("td,edf->tef", x.astype(jnp.float32),
                   experts_wu.astype(jnp.float32))
    act = swish(h) * u
    y = jnp.einsum("tef,efd->ted", act, experts_wd.astype(jnp.float32))
    return jnp.einsum("ted,te->td", y, gate).astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused softmax cross-entropy (vocab-chunk online logsumexp)
# ---------------------------------------------------------------------------


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Per-token CE loss. logits (T, V) fp32-safe; labels (T,) int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[:, None], axis=-1)[:, 0]
    return lse - gold
