"""Tiled matmul Pallas kernel (MXU-aligned, f32 VMEM accumulator)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import compiler_params_for


def _matmul_kernel(a_ref, b_ref, out_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _store():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret", "platform"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 128,
           interpret: bool = True,
           platform: str | None = None) -> jax.Array:
    """C = A @ B with (block_m, block_n, block_k) VMEM tiles.

    A (M, K), B (K, N); M/N/K must be divisible by the block sizes
    (the ops.py wrapper pads otherwise).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, s: (i, s)),
            pl.BlockSpec((block_k, block_n), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=compiler_params_for(
            platform, dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
